"""Sanitizer smoke: run representative tiny cases under CHARON_SANITIZE=1.

CI runs this with the env knob set; locally it forces sanitize mode on
regardless.  Deliberately standalone — it must NOT go through
``benchmarks/run.py`` (which rewrites BENCH_sim.json and would skew the
committed throughput baselines the regression guards compare against).

Covers the three cache surfaces the sanitizer wraps:

* core ``Simulator.run`` (ingest/passes/block_times/memory/reports
  buckets), cold then warm, plus a tiny sweep (bench_explore's shape at
  toy scale) so the sweep path's cache hits are re-verified too;
* the serving ``StepOracle`` front memos + serving bucket via a
  request-level run;
* ``check_determinism`` on both specs (cold/warm/uncached/pickled
  bit-identity).

Exits non-zero on any CacheSanitizerError / determinism mismatch.
"""
from __future__ import annotations

import dataclasses
import os
import sys

os.environ.setdefault("CHARON_SANITIZE", "1")

from repro.analysis.sanitize import check_determinism, sanitize_enabled
from repro.api import (Cluster, DecodeWorkload, ServingWorkload, SimSpec,
                       SweepSpace, TrainWorkload, sweep)
from repro.configs import get_config
from repro.core import Simulator


def main() -> int:
    assert sanitize_enabled(), "CHARON_SANITIZE not set"
    cfg = dataclasses.replace(get_config("gemma-7b"), name="sanitize-tiny",
                              num_layers=2, d_model=128, num_heads=2,
                              num_kv_heads=2, d_ff=256, vocab_size=512)
    sim = Simulator("tpu_v5e", engine="analytical")
    from repro.analysis.sanitize import SanitizingSimCache
    assert isinstance(sim.cache, SanitizingSimCache), \
        "env knob did not activate the sanitizing cache"

    train = SimSpec(cfg, cluster=Cluster("tpu_v5e", chips=4),
                    workload=TrainWorkload(global_batch=8, seq_len=128))
    cold = sim.run(train)
    warm = sim.run(train)
    assert cold == warm, "warm run diverged under sanitizer"
    print(f"train step {cold.step_time_us:.1f} us (warm verified)")

    base = SimSpec(cfg, cluster=Cluster("tpu_v5e", chips=4),
                   workload=DecodeWorkload(seq_len=256))
    res = sweep(SweepSpace(base, {"tp": (1, 2), "batch": (8, 16)}), sim=sim)
    assert res.evaluated, "sweep produced no candidates"
    print(f"sweep: {len(res.evaluated)} evaluated, "
          f"{len(res.pruned)} pruned (every cache hit re-verified)")

    serving = SimSpec(cfg, workload=ServingWorkload(
        n_requests=40, rate_rps=40.0, seed=3, max_batch=8))
    from repro.serving.sim import ServingSimulator
    srep = ServingSimulator(sim).run(serving)
    assert srep.n_requests == 40
    print(f"serving: {srep.n_requests} requests, oracle memo hits verified")

    for name, spec in (("train", train), ("serving", serving)):
        rep = check_determinism(spec, raise_on_mismatch=True)
        print(f"determinism[{name}]: {rep.render()}")

    print("sanitize smoke OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Paper Fig. 11 — accuracy/consistency across GPUs and cluster scales.

(a) Cross-"hardware": simulate the same workload on every HardwareSpec and
    verify scaling follows the spec ratios (the paper validates across
    A100/H800/H20/L20; without those chips we verify internal consistency
    and report the predicted per-chip step times).
(b) Cluster scale: 16 -> 8192 chips with mixed DP/TP/PP/(EP)/SP — the
    simulator's structural numbers (collective traffic, flops) are
    cross-validated against the XLA dry-run records at the 256-chip point.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.api import Cluster, SimSpec, TrainWorkload
from repro.configs import get_config
from repro.core import ParallelConfig, Simulator

REPO = Path(__file__).resolve().parents[1]


def run() -> list[dict]:
    rows = []
    cfg = get_config("gemma-7b")

    # ---- (a) cross-hardware consistency ----
    base = None
    for hw in ("tpu_v5e", "tpu_v5p", "a100_80g", "h100_sxm"):
        sim = Simulator(hw, engine="analytical")
        par = ParallelConfig(tp=8, dp=4, sp=8, zero_stage=1)
        r = sim.run(SimSpec(cfg, cluster=Cluster(hw), parallel=par,
                            workload=TrainWorkload(global_batch=64,
                                                   seq_len=4096)))
        if base is None:
            base = r.step_time_us
        rows.append({"bench": "fig11_scale", "case": f"hw/{hw}",
                     "chips": 32, "step_ms": round(r.step_time_us / 1e3, 1),
                     "mfu": round(r.mfu, 3),
                     "rel_speed": round(base / r.step_time_us, 2)})

    # ---- (b) cluster-scale sweep (v5e), mixed parallelism ----
    sim = Simulator("tpu_v5e", engine="analytical")
    sweeps = [
        (16, ParallelConfig(tp=16, dp=1, sp=16, zero_stage=1)),
        (64, ParallelConfig(tp=16, dp=4, sp=16, zero_stage=1)),
        (256, ParallelConfig(tp=16, dp=16, sp=16, zero_stage=1)),
        (1024, ParallelConfig(tp=16, dp=32, pp=2, sp=16, zero_stage=1,
                              microbatches=8)),
        (4096, ParallelConfig(tp=16, dp=64, pp=2, pods=2, sp=16, zero_stage=1,
                              microbatches=8)),
        (8192, ParallelConfig(tp=16, dp=64, pp=4, pods=2, sp=16, zero_stage=1,
                              microbatches=16)),
    ]
    prev_tps = 0.0
    weak_ok = True
    for chips, par in sweeps:
        gb = max(chips // 16, 1) * 64
        r = sim.run(SimSpec(cfg, cluster=Cluster("tpu_v5e"), parallel=par,
                            workload=TrainWorkload(global_batch=gb,
                                                   seq_len=4096)))
        rows.append({"bench": "fig11_scale", "case": f"chips/{chips}",
                     "chips": chips, "global_batch": gb,
                     "step_ms": round(r.step_time_us / 1e3, 1),
                     "tokens_per_s": round(r.tokens_per_s),
                     "mfu": round(r.mfu, 3)})
        if r.tokens_per_s < prev_tps:
            weak_ok = False
        prev_tps = r.tokens_per_s
    rows.append({"bench": "fig11_scale", "case": "weak_scaling_monotone",
                 "ok": weak_ok})

    # ---- cross-validation vs XLA dry-run at 256 chips ----
    rec_path = REPO / "results" / "dryrun" / "gemma-7b__train_4k__single.json"
    if rec_path.exists():
        rec = json.loads(rec_path.read_text())
        par = ParallelConfig(tp=16, dp=16, sp=16, zero_stage=rec["zero_stage"])
        r = sim.run(SimSpec(cfg, cluster=Cluster("tpu_v5e"), parallel=par,
                            workload=TrainWorkload(global_batch=256,
                                                   seq_len=4096)))
        sim_flops_dev = r.model_flops / 256  # useful flops per device
        xla_flops_dev = rec["flops_per_device"]
        rows.append({
            "bench": "fig11_scale", "case": "xval_vs_xla_dryrun/gemma_train_4k",
            "sim_model_flops_per_dev": f"{sim_flops_dev:.3e}",
            "xla_hlo_flops_per_dev": f"{xla_flops_dev:.3e}",
            "hlo_to_model_ratio": round(xla_flops_dev / sim_flops_dev, 2),
            "note": "HLO/model ratio = remat + causal-waste + CE overhead",
        })
    return rows

"""Paper Fig. 7 — end-to-end simulation accuracy vs ground truth.

Ground truth = real XLA-CPU execution of tiny-scale models; simulator = fused
backend with CPU-profiled operators.  Per-(mode, family) calibration factors
are fitted on TWO calibration models (gemma=dense, olmoe=moe) — the paper's
"re-calibrated according to the profiling results" — then evaluated on
HELD-OUT architectures (yi, qwen2.5, phi4 dense; deepseek-v3 MoE+MLA).
The paper's headline: overall error < 5.35 %.
"""
from __future__ import annotations

from benchmarks.common import make_cpu_simulator, measure_real, simulate
from repro.configs import get_tiny_config

# decode at (8, 512): large enough to beat single-core timing jitter, small
# enough that the container's ~0.7 GB/s effective bf16 stream bandwidth does
# not reduce the step to a pure cache-copy microbenchmark (see EXPERIMENTS.md)
MODES = [("train", 2, 128), ("prefill", 2, 256), ("decode", 8, 512)]
CALIB_MODELS = {"dense": "gemma-7b", "moe": "olmoe-1b-7b"}
HELDOUT = [
    ("llama3-8b(analogue)", "yi-34b", "dense"),
    ("qwen3-8b(analogue)", "qwen2.5-32b", "dense"),
    ("phi4-mini", "phi4-mini-3.8b", "dense"),
    ("qwen3-30b-a3b(analogue)", "deepseek-v3-671b", "moe"),
]


def run() -> list[dict]:
    sim = make_cpu_simulator("fused")
    # ---- calibration pass (paper: calibrated slowdown factors) ----
    calib: dict[tuple[str, str], float] = {}
    for fam, arch in CALIB_MODELS.items():
        cfg = get_tiny_config(arch)
        for mode, B, S in MODES:
            real = measure_real(cfg, mode=mode, B=B, S=S)
            pred = simulate(sim, cfg, mode=mode, B=B, S=S)
            calib[(mode, fam)] = real / pred
    # ---- held-out evaluation ----
    rows = []
    for name, arch, fam in HELDOUT:
        cfg = get_tiny_config(arch)
        for mode, B, S in MODES:
            real = measure_real(cfg, mode=mode, B=B, S=S)
            pred = simulate(sim, cfg, mode=mode, B=B, S=S,
                            calib=calib[(mode, fam)])
            err = abs(pred - real) / real * 100
            rows.append({"bench": "fig7_accuracy", "case": f"{name}/{mode}",
                         "real_us": round(real, 1), "sim_us": round(pred, 1),
                         "error_pct": round(err, 2)})
    sim.db.save()
    tp_errs = [r["error_pct"] for r in rows if "/decode" not in r["case"]]
    dec_errs = [r["error_pct"] for r in rows if "/decode" in r["case"]]
    rows.append({"bench": "fig7_accuracy", "case": "OVERALL(train+prefill,held-out)",
                 "error_pct": round(sum(tp_errs) / len(tp_errs), 2),
                 "max_error_pct": round(max(tp_errs), 2),
                 "paper_claim": "overall error < 5.35%",
                 "calibration": {f"{m}/{f}": round(v, 3)
                                 for (m, f), v in calib.items()}})
    rows.append({"bench": "fig7_accuracy", "case": "OVERALL(decode,held-out)",
                 "error_pct": round(sum(dec_errs) / len(dec_errs), 2),
                 "max_error_pct": round(max(dec_errs), 2),
                 "caveat": "XLA-CPU copies loop-carried KV caches (no in-place "
                           "aliasing through while bodies) — a backend artifact "
                           "absent on TPU; see EXPERIMENTS.md §Accuracy"})
    return rows

"""Request-level serving what-ifs: policies and the goodput objective.

    PYTHONPATH=src python examples/simulate_serving.py

Part 1 replays one synthetic bursty workload through four batching policies
and prints the latency/goodput table a deployment decision reads.  Part 2
runs the same declarative sweep twice over the candidates — ranked by
steady-state step time vs by request-level SLO goodput — and shows that the
two objectives pick different winners (the docs/serving.md scenario).
Part 3 scales the same spec surface to a fleet: a diurnal trace through
routed replicas with a queue-depth autoscaler (docs/serving.md, "Fleet
simulation").
"""
import time

from repro.api import (
    AutoscalerSpec, Cluster, DecodeWorkload, FleetSpec, RouterSpec,
    ServingWorkload, SimSpec, SweepSpace, sweep,
)
from repro.configs import get_config
from repro.core import ParallelConfig, Simulator
from repro.serving.sim import (
    SLO, ChunkedPrefill, ContinuousBatching, DisaggregatedPD, LengthDist,
    ServingSimulator, StaticBatching,
)

cfg = get_config("xlstm-125m")
sim = Simulator("tpu_v5e", engine="analytical")
par = ParallelConfig(tp=2)

# ---- part 1: one workload spec, four policies --------------------------
sw = ServingWorkload(
    n_requests=300, arrival="bursty", rate_rps=60.0, burst_factor=4.0,
    prompt=LengthDist("lognormal", median=64.0, sigma=0.6, cap=512),
    output=LengthDist("lognormal", median=24.0, sigma=0.5, cap=96),
    seed=42, slo=SLO(ttft_s=0.5, tpot_ms=5.0), max_batch=16)
wl = sw.build()
policies = [ContinuousBatching(16),
            ChunkedPrefill(16, token_budget=128),
            StaticBatching(16),
            DisaggregatedPD(prefill_batch=2, decode_batch=16,
                            transfer_s=0.002)]

print(f"{wl.n_requests} bursty requests, "
      f"{wl.prompt_tokens + wl.output_tokens} tokens, "
      f"SLO: TTFT<={sw.slo.ttft_s}s TPOT<={sw.slo.tpot_ms}ms\n")
print(f"{'policy':>14} {'wall_s':>7} {'ttft_p50':>9} {'ttft_p99':>9} "
      f"{'tpot_p50':>9} {'attain':>7} {'goodput':>8}")
for pol in policies:
    t0 = time.perf_counter()
    rep = ServingSimulator(sim, cfg, par=par, policy=pol).run(wl, slo=sw.slo)
    wall = time.perf_counter() - t0
    print(f"{pol.name:>14} {wall:7.2f} {rep.ttft_s.p50:9.4f} "
          f"{rep.ttft_s.p99:9.4f} {rep.tpot_ms.p50:9.3f} "
          f"{rep.slo_attainment:7.3f} {rep.goodput_rps:8.2f}")

# (the one-spec path: ServingSimulator(sim).run(spec) prices the whole
# trace with the policy/SLO carried by the spec itself)
spec = SimSpec(cfg, cluster=Cluster("tpu_v5e"), parallel=par, workload=sw)
rep = ServingSimulator(sim).run(spec)
print(f"{'spec:' + sw.policy:>14} {'-':>7} {rep.ttft_s.p50:9.4f} "
      f"{rep.ttft_s.p99:9.4f} {rep.tpot_ms.p50:9.3f} "
      f"{rep.slo_attainment:7.3f} {rep.goodput_rps:8.2f}")

# ---- part 2: step-time vs goodput ranking in the sweep ------------------
heavy = ServingWorkload(
    n_requests=240, rate_rps=2000.0,
    prompt=LengthDist("lognormal", median=64.0, sigma=0.5, cap=256),
    output=LengthDist("fixed", value=24), seed=11,
    slo=SLO(ttft_s=0.05, tpot_ms=2.0))
base = SimSpec(cfg, cluster=Cluster("tpu_v5e", chips=8),
               workload=DecodeWorkload(seq_len=512))
res = sweep(SweepSpace(base, {"tp": (1, 2, 4), "pp": (1,),
                              "batch": (8, 32, 128)}),
            sim=sim, objective="goodput", scenario=heavy)

print("\nsweep ranking under each objective "
      "(tp/batch, step_us, system goodput rps):")
for name in ("step_time", "goodput"):
    row = ["  %s:" % name.rjust(9)]
    for r in res.ranked(name)[:4]:
        row.append(f"tp{r.cand.par.tp}/b{r.cand.global_batch} "
                   f"({r.report.step_time_us:.0f}us, {r.goodput_rps:.0f}rps)")
    print("  ".join(row))
best_s, best_g = res.ranked("step_time")[0], res.ranked("goodput")[0]
print(f"\nstep-time winner tp{best_s.cand.par.tp}/b{best_s.cand.global_batch} "
      f"vs goodput winner tp{best_g.cand.par.tp}/b{best_g.cand.global_batch}: "
      f"the lowest-latency step starves admission capacity under load.")

# ---- part 3: a fleet on the same spec surface ---------------------------
# a diurnal trace routed over least-loaded replicas, with an autoscaler
# activating standbys on queue depth; non-trivial fleet => FleetReport
fleet_spec = SimSpec(
    cfg, cluster=Cluster("tpu_v5e"), parallel=par,
    workload=ServingWorkload(
        n_requests=3000, arrival="diurnal", rate_rps=120.0, period_s=60.0,
        prompt=LengthDist("lognormal", median=64.0, sigma=0.6, cap=512),
        output=LengthDist("lognormal", median=24.0, sigma=0.5, cap=96),
        seed=42, slo=SLO(ttft_s=0.5, tpot_ms=5.0), max_batch=16,
        fleet=FleetSpec(replicas=2, router=RouterSpec("least_loaded"),
                        autoscaler=AutoscalerSpec(min_replicas=2,
                                                  max_replicas=6))))
frep = ServingSimulator(sim).run(fleet_spec)
ups = sum(1 for e in frep.autoscaler_trace
          if e["action"].startswith("scale_up"))
print(f"\nfleet: {frep.n_requests} diurnal requests over "
      f"{frep.n_replicas} replicas ({frep.router} router, {ups} scale-ups): "
      f"ttft_p99={frep.ttft_s.p99:.3f}s attain={frep.slo_attainment:.3f} "
      f"goodput={frep.goodput_rps:.1f}rps")
print("per-replica requests:", dict(sorted(frep.replica_requests.items())))

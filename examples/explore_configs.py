"""Design-space exploration example (paper Fig. 13 workflow): find the
serving config maximising TPS/chip under a TPOT SLO for qwen2.5-32b on a
v5e-256 pod.

    PYTHONPATH=src python examples/explore_configs.py

The sweep is declarative: a base ``SimSpec`` plus named axes over any spec
field.  Here it reproduces the classic (tp, pp, batch) grid; see
``examples/sweep_whatif.py`` for axes the old ``explore()`` could not
express (seq_len, quantization, hardware).
"""
from repro.api import Cluster, DecodeWorkload, SimSpec, SweepSpace, sweep
from repro.configs import get_config
from repro.core import Simulator

cfg = get_config("qwen2.5-32b")
sim = Simulator("tpu_v5e", engine="analytical")

base = SimSpec(cfg, cluster=Cluster("tpu_v5e", chips=256, memory_limit=16e9),
               workload=DecodeWorkload(seq_len=8192))
res = sweep(SweepSpace(base, {"tp": (4, 8, 16, 32), "pp": (1, 2, 4),
                              "batch": (16, 32, 64, 128, 256)}), sim=sim)
print(f"evaluated {len(res.evaluated)} configs "
      f"({len(res.pruned)} pruned) in {res.wall_time_s:.1f}s\n")

print("Pareto frontier (TPS/user vs TPS/chip):")
for r in res.pareto():
    p = r.cand.par
    print(f"  tp{p.tp:<2} pp{p.pp} dp{p.dp:<2} batch{r.cand.global_batch:<4} "
          f"TPOT {r.report.step_time_us/1e3:6.2f} ms  "
          f"TPS/user {r.tps_per_user:6.1f}  TPS/chip {r.tps_per_chip:6.2f}  "
          f"mem {r.report.memory.total/1e9:5.1f} GB")

for slo in (30.0, 15.0, 8.0):
    best = res.best_under_slo(tpot_ms=slo)
    if best:
        p = best.cand.par
        print(f"\nbest under {slo:.0f} ms TPOT: tp{p.tp}/pp{p.pp}/"
              f"batch{best.cand.global_batch} -> "
              f"{best.tps_per_chip:.2f} TPS/chip, "
              f"{best.report.step_time_us/1e3:.2f} ms TPOT")

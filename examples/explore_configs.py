"""Design-space exploration example (paper Fig. 13 workflow): find the
serving config maximising TPS/chip under a TPOT SLO for qwen2.5-32b on a
v5e-256 pod.

    PYTHONPATH=src python examples/explore_configs.py
"""
from repro.configs import get_config
from repro.core import Simulator
from repro.core.explorer import explore

cfg = get_config("qwen2.5-32b")
sim = Simulator("tpu_v5e", engine="analytical")

res = explore(sim, cfg, mode="decode", seq_len=8192, chips=256,
              tp_choices=(4, 8, 16, 32), pp_choices=(1, 2, 4),
              batch_choices=(16, 32, 64, 128, 256), memory_limit=16e9)
print(f"evaluated {len(res.evaluated)} configs "
      f"({len(res.pruned)} pruned) in {res.wall_time_s:.1f}s\n")

print("Pareto frontier (TPS/user vs TPS/chip):")
for r in res.pareto():
    p = r.cand.par
    print(f"  tp{p.tp:<2} pp{p.pp} dp{p.dp:<2} batch{r.cand.global_batch:<4} "
          f"TPOT {r.report.step_time_us/1e3:6.2f} ms  "
          f"TPS/user {r.tps_per_user:6.1f}  TPS/chip {r.tps_per_chip:6.2f}  "
          f"mem {r.report.memory.total/1e9:5.1f} GB")

for slo in (30.0, 15.0, 8.0):
    best = res.best_under_slo(tpot_ms=slo)
    if best:
        p = best.cand.par
        print(f"\nbest under {slo:.0f} ms TPOT: tp{p.tp}/pp{p.pp}/"
              f"batch{best.cand.global_batch} -> "
              f"{best.tps_per_chip:.2f} TPS/chip, "
              f"{best.report.step_time_us/1e3:.2f} ms TPOT")

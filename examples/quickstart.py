"""Quickstart: simulate LLM training + serving performance in 20 lines.

    PYTHONPATH=src python examples/quickstart.py

One frozen ``SimSpec`` describes any simulation — model + cluster +
parallelism + workload — and ``Simulator.run(spec)`` prices it.
"""
from repro.api import Cluster, DecodeWorkload, SimSpec, TrainWorkload
from repro.configs import get_config
from repro.core import ParallelConfig, Simulator

# any assigned architecture: --arch ids from repro.configs.ARCH_IDS
cfg = get_config("qwen2.5-32b")

# a TPU v5e pod: 16-way tensor/sequence parallel x 16-way data parallel
par = ParallelConfig(tp=16, dp=16, sp=16, zero_stage=1)
cluster = Cluster("tpu_v5e", chips=256)
sim = Simulator("tpu_v5e", engine="analytical")

train = sim.run(SimSpec(cfg, cluster=cluster, parallel=par,
                        workload=TrainWorkload(global_batch=256,
                                               seq_len=4096)))
print(f"train_4k @ v5e-256: {train.step_time_us/1e3:8.1f} ms/step   "
      f"MFU {train.mfu:.2%}   {train.tokens_per_s:,.0f} tok/s")
print(f"  breakdown(ms): " + ", ".join(
    f"{k}={v/1e3:.0f}" for k, v in train.breakdown_us.items()))
print(f"  memory/device: {train.memory.total/1e9:.1f} GB "
      f"(weights {train.memory.weights/1e9:.1f}, "
      f"activations {train.memory.activations_peak/1e9:.1f}, "
      f"saved {train.memory.saved_activations/1e9:.1f})")

decode = sim.run(SimSpec(cfg, cluster=cluster, parallel=par,
                         workload=DecodeWorkload(global_batch=128,
                                                 seq_len=32768)))
print(f"decode_32k: TPOT {decode.tpot_ms:.1f} ms   "
      f"{decode.tps_per_chip:.1f} tok/s/chip   "
      f"KV cache {decode.memory.kv_cache/1e9:.1f} GB/device")

"""Sweep axes the legacy ``explore()`` grid could not express.

    PYTHONPATH=src python examples/sweep_whatif.py

One declarative ``SweepSpace`` over seq_len x quantization x hardware:
"should we serve 8k contexts on v5e in int8, or pay for H100s and keep
bf16?" — a two-hardware what-if the old ``explore(tp_choices=...)``
signature (hardwired to tp/pp/batch/micro on one simulator) had no words
for.  Every axis is just a ``SimSpec`` field name.
"""
from repro.api import Cluster, DecodeWorkload, SimSpec, SweepSpace, sweep
from repro.configs import get_config
from repro.core import ParallelConfig, Simulator

cfg = get_config("qwen2.5-32b")

base = SimSpec(cfg, cluster=Cluster("tpu_v5e", chips=16),
               parallel=ParallelConfig(tp=8),
               workload=DecodeWorkload(global_batch=32))
space = SweepSpace(base, {
    "seq_len": (2048, 8192),
    "quantize": (None, "int8"),
    "hardware": ("tpu_v5e", "h100_sxm"),
})

print(f"sweeping {space.size()} specs over axes {space.axis_names} ...")
res = sweep(space, sim=Simulator("tpu_v5e", engine="analytical"))
print(f"evaluated {len(res.evaluated)} in {res.wall_time_s:.1f}s "
      f"({res.configs_per_sec:.1f} configs/s, {res.n_groups} reuse groups)\n")

print(f"{'hardware':>10} {'seq':>6} {'quant':>6} {'TPOT_ms':>8} "
      f"{'TPS/chip':>9} {'KV GB':>6}")
for r in res.ranked():
    w, c = r.spec.workload, r.spec.cluster
    print(f"{c.hardware:>10} {w.seq_len:>6} {w.quantize or 'bf16':>6} "
          f"{r.report.step_time_us/1e3:8.2f} {r.tps_per_chip:9.2f} "
          f"{r.report.memory.kv_cache/1e9:6.2f}")

best = res.ranked()[0]
print(f"\nfastest step: {best.spec.cluster.hardware} @ "
      f"seq {best.spec.workload.seq_len}, "
      f"{best.spec.workload.quantize or 'bf16'}; per-layer cache hit rates: "
      + ", ".join(f"{k}={v['hits']}/{v['hits']+v['misses']}"
                  for k, v in sorted(res.cache_stats.items())
                  if isinstance(v, dict) and "hits" in v))

"""What-if analyses via compiler-style passes (paper §5): evaluate operator
fusion, int8 quantization, remat policy and the DualPipe schedule WITHOUT
implementing them in a real compiler — just toggle passes and re-simulate.

    PYTHONPATH=src python examples/whatif_passes.py
"""
from repro.configs import get_config
from repro.core import ParallelConfig, Simulator

cfg = get_config("yi-34b")
sim = Simulator("tpu_v5e", engine="analytical")
base_par = ParallelConfig(tp=16, dp=8, pp=2, sp=16, zero_stage=1, microbatches=8)

base = sim.simulate(cfg, mode="train", global_batch=256, seq_len=4096,
                    par=base_par)
print(f"{'baseline':28s} {base.step_time_us/1e3:9.1f} ms  MFU {base.mfu:.3f}")

whatifs = {
    "+ operator fusion": dict(fusion=True),
    "+ int8 matmul quant": dict(quantize="int8"),
    "+ remat=dots (save matmuls)": dict(remat="dots"),
    "+ no remat (memory perm.)": dict(remat="none"),
}
for name, kw in whatifs.items():
    r = sim.simulate(cfg, mode="train", global_batch=256, seq_len=4096,
                     par=base_par, **kw)
    print(f"{name:28s} {r.step_time_us/1e3:9.1f} ms  MFU {r.mfu:.3f}  "
          f"mem {r.memory.total/1e9:.0f} GB  "
          f"({base.step_time_us/r.step_time_us:.2f}x)")

dual = ParallelConfig(tp=16, dp=8, pp=2, sp=16, zero_stage=1, microbatches=8,
                      pp_schedule="dualpipe")
r = sim.simulate(cfg, mode="train", global_batch=256, seq_len=4096, par=dual)
print(f"{'+ DualPipe schedule':28s} {r.step_time_us/1e3:9.1f} ms  MFU {r.mfu:.3f}  "
      f"bubble {r.pp.bubble_fraction:.1%} vs {base.pp.bubble_fraction:.1%}")

"""What-if analyses via compiler-style passes (paper §5): evaluate operator
fusion, int8 quantization, remat policy and the DualPipe schedule WITHOUT
implementing them in a real compiler — each what-if is one field change on a
frozen ``SimSpec`` (``spec_replace`` takes dotted spec paths), re-simulated.

    PYTHONPATH=src python examples/whatif_passes.py
"""
from repro.api import Cluster, SimSpec, TrainWorkload, spec_replace
from repro.configs import get_config
from repro.core import ParallelConfig, Simulator

cfg = get_config("yi-34b")
sim = Simulator("tpu_v5e", engine="analytical")
base_par = ParallelConfig(tp=16, dp=8, pp=2, sp=16, zero_stage=1,
                          microbatches=8)

base_spec = SimSpec(cfg, cluster=Cluster("tpu_v5e"), parallel=base_par,
                    workload=TrainWorkload(global_batch=256, seq_len=4096))
base = sim.run(base_spec)
print(f"{'baseline':28s} {base.step_time_us/1e3:9.1f} ms  MFU {base.mfu:.3f}")

whatifs = {
    "+ operator fusion": {"workload.fusion": True},
    "+ int8 matmul quant": {"workload.quantize": "int8"},
    "+ remat=dots (save matmuls)": {"workload.remat": "dots"},
    "+ no remat (memory perm.)": {"workload.remat": "none"},
}
for name, changes in whatifs.items():
    r = sim.run(spec_replace(base_spec, changes))
    print(f"{name:28s} {r.step_time_us/1e3:9.1f} ms  MFU {r.mfu:.3f}  "
          f"mem {r.memory.total/1e9:.0f} GB  "
          f"({base.step_time_us/r.step_time_us:.2f}x)")

r = sim.run(spec_replace(base_spec, {"parallel.pp_schedule": "dualpipe"}))
print(f"{'+ DualPipe schedule':28s} {r.step_time_us/1e3:9.1f} ms  MFU {r.mfu:.3f}  "
      f"bubble {r.pp.bubble_fraction:.1%} vs {base.pp.bubble_fraction:.1%}")

"""End-to-end training driver example: trains a reduced qwen2.5 config on CPU
for a few hundred steps with checkpointing, restart recovery and straggler
monitoring.  The same driver lowers the full configs on the production mesh
(see launch/dryrun.py for the compile proof).

    PYTHONPATH=src python examples/train_lm.py
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    main([
        "--arch", "qwen2.5-32b", "--tiny",
        "--steps", "200", "--batch", "8", "--seq", "128",
        "--optimizer", "adamw", "--remat", "none",
        "--ckpt-every", "50", "--ckpt-dir", "/tmp/repro_quickstart_ckpt",
    ] + sys.argv[1:])

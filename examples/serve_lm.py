"""Serving example: continuous batching with mixed-length requests.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax

from repro.configs import get_tiny_config
from repro.models import Model
from repro.serving import Request, ServingEngine

cfg = get_tiny_config("gemma-7b")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))

engine = ServingEngine(cfg, params, slots=4, cache_len=128)
prompts = [
    [1, 5, 9, 12], [7, 3], [2, 2, 2, 2, 2, 2], [11, 4, 8],
    [6, 6, 6], [9, 1, 2, 3, 4, 5],
]
t0 = time.perf_counter()
for i, p in enumerate(prompts):
    engine.submit(Request(rid=i, prompt=p, max_new_tokens=12))

finished = engine.run_until_drained()
wall = time.perf_counter() - t0
tokens = sum(len(r.tokens) for r in finished)
print(f"served {len(finished)} requests / {tokens} tokens "
      f"in {wall*1e3:.0f} ms ({tokens/wall:.1f} tok/s on 1 CPU core)")
for r in sorted(finished, key=lambda r: r.rid):
    print(f"  req{r.rid}: prompt={len(r.prompt)} toks, "
          f"TTFT {r.ttft_s*1e3:6.1f} ms, out={r.tokens}")
